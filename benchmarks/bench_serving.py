"""Serving-path benchmark: drives the continuous-batching decode driver
directly (smoke arch, N steps) for the f32 baseline and the packed int8
fast path, and emits tok/s, weight bytes/step, and the packed-vs-f32 ratio.

Off-TPU the kernels run in interpret mode, so the tok/s numbers validate
plumbing and the byte ratios are exact storage facts; real rates need a TPU.
Regenerate the full §Perf serving ladder with ``repro.launch.serve`` over
archs x bit-widths (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from benchmarks.common import emit

ARCH = "yi-6b"
STEPS = 12
BATCH = 2
S_MAX = 32
PROMPT = 8


def main():
    from repro.api import PrecisionPolicy, RunSpec, Session

    rows = {}
    for bits, tag in ((32, "f32"), (7, "int8")):
        precision = (PrecisionPolicy.lazy_int8(bits) if bits < 32
                     else PrecisionPolicy.full_precision())
        spec = RunSpec(arch=ARCH, workload="serve", smoke=True, batch=BATCH,
                       seq=S_MAX, precision=precision,
                       options={"steps": STEPS, "prompt_len": PROMPT,
                                "attn_impl": "ref", "quiet": True})
        stats = Session(spec).serve()
        rows[tag] = stats
        us_per_step = stats.wall_s / max(stats.decode_steps, 1) * 1e6
        emit(f"serving_{ARCH}_smoke_{tag}", us_per_step,
             f"tok_s={stats.tok_s:.1f};bytes_step={stats.bytes_per_step_packed};"
             f"completed={stats.completed};admitted={stats.admitted}")
    ratio = (rows["int8"].bytes_per_step_packed
             / max(rows["f32"].bytes_per_step_f32, 1))
    emit(f"serving_{ARCH}_smoke_packed_vs_f32", ratio * 100.0,
         f"packed_bytes={rows['int8'].bytes_per_step_packed};"
         f"f32_bytes={rows['f32'].bytes_per_step_f32}")
    assert ratio < 1 / 3, (
        f"int8 serving path must stream < 1/3 the f32 weight bytes, got {ratio:.3f}")
    return rows


if __name__ == "__main__":
    main()
