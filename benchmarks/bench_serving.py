"""Serving-path benchmark — a thin wrapper over the
``serve-precision-ablation`` sweep preset (kv-cache axis pinned to f32 for
the CI smoke; the full kv ablation is the preset's default grid).

Two regressions are asserted on every run:

* the int8 weight path streams < 1/3 the f32 weight bytes per decode step;
* the PAGED KV cache reserves strictly fewer bytes than the contiguous
  slab on the mixed-length workload (ragged prompts + staggered max_new —
  the workload where per-slot ``s_max`` provisioning is pure waste).

Off-TPU the kernels run in interpret mode, so the tok/s numbers validate
plumbing and the byte ratios are exact storage facts; real rates need a TPU.
Regenerate the full §Perf serving ladder with ``repro-sweep run
serve-precision-ablation`` (see EXPERIMENTS.md).
"""

from __future__ import annotations

from benchmarks.common import bench_output, bench_row, emit
from repro.sweep import ResultsStore, SweepRunner, get_preset

ARCH = "yi-6b"
STEPS = 12


def main():
    sweep = get_preset("serve-precision-ablation", steps=STEPS, arch=ARCH,
                       weights=(32, 7), kv_cache=(32,),
                       kv_layout=("paged", "contiguous"))
    # force=True: this is the CI regression smoke — always exercise the
    # driver, never replay the store.  The recording goes to an ignored
    # scratch dir so repeated runs don't dirty the committed grid store.
    store = ResultsStore.for_sweep(sweep, "results/bench")
    out = SweepRunner(sweep, store, quiet=True).run(force=True)
    assert not out["failed"], out

    rows = {}
    with bench_output("serving") as jrows:
        for cell in sweep.cells():
            m = store.get(cell.key)["metrics"]
            tag = ("f32" if m["bits"] >= 32 else "int8") + "-" + m["kv_layout"]
            rows[tag] = m
            us_per_step = m["wall_s"] / max(m["decode_steps"], 1) * 1e6
            emit(f"serving_{ARCH}_smoke_{tag}", us_per_step,
                 f"tok_s={m['tok_s']:.1f};"
                 f"bytes_step={m['bytes_per_step_packed']};"
                 f"kv_bytes={m['kv_bytes']};"
                 f"completed={m['completed']};admitted={m['admitted']}")
        ratio = (rows["int8-paged"]["bytes_per_step_packed"]
                 / max(rows["f32-paged"]["bytes_per_step_f32"], 1))
        emit(f"serving_{ARCH}_smoke_packed_vs_f32", ratio * 100.0,
             f"packed_bytes={rows['int8-paged']['bytes_per_step_packed']};"
             f"f32_bytes={rows['f32-paged']['bytes_per_step_f32']}")
        jrows.append(bench_row(f"serving_{ARCH}_smoke", "packed_vs_f32",
                               ratio, "ratio"))
        kv_ratio = (rows["int8-paged"]["kv_bytes"]
                    / max(rows["int8-contiguous"]["kv_bytes"], 1))
        emit(f"serving_{ARCH}_smoke_paged_vs_contig_kv", kv_ratio * 100.0,
             f"paged_kv={rows['int8-paged']['kv_bytes']};"
             f"contig_kv={rows['int8-contiguous']['kv_bytes']};"
             f"page={rows['int8-paged']['page_size']}")
        jrows.append(bench_row(f"serving_{ARCH}_smoke", "paged_vs_contig_kv",
                               kv_ratio, "ratio"))
    assert ratio < 1 / 3, (
        f"int8 serving path must stream < 1/3 the f32 weight bytes, got {ratio:.3f}")
    assert kv_ratio < 1, (
        f"paged KV footprint must be strictly below contiguous on the "
        f"mixed-length workload, got {kv_ratio:.3f}")
    return rows


if __name__ == "__main__":
    main()
