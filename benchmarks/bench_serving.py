"""§Perf serving ladder table from results/hillclimb.json (regenerable via
repro.launch.dryrun --serve-bits etc.; see EXPERIMENTS.md §Perf)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit


def main():
    path = "results/hillclimb.json"
    if not os.path.exists(path):
        emit("perf_ladder_missing", 0.0, "run the §Perf ladder first")
        return []
    rows = [r for r in json.load(open(path)) if r.get("status") == "ok"]
    for r in rows:
        v = r.get("variant") or {}
        tag = "+".join(f"{k}={vv}" for k, vv in v.items()) or "baseline"
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"perf_{r['arch']}_{r['shape']}_{tag}", step * 1e6,
             f"compute={r['compute_s']:.2e};mem={r['memory_s']:.2e};"
             f"coll={r['collective_s']:.2e};useful={r['useful_flops_ratio']:.3f}")
    return rows


if __name__ == "__main__":
    main()
