"""Paper Fig. 2: convergence + energy for FWQ vs Full-Precision / Unified-Q /
Rand-Q (CNN on synthetic-CIFAR, non-iid clients)."""

from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.energy import heterogeneous_fleet, memory_capacities
from repro.data import ClientBatcher, SyntheticImages, dirichlet_partition
from repro.fed import FLOrchestrator, FLSimulation, OrchestratorConfig, SimConfig
from repro.models.cnn import mobilenet, resnet, xent_loss


def run_scheme(scheme: str, *, n_clients=8, rounds=60, seed=0, model_kind="resnet"):
    model = (mobilenet(width=8, n_stages=2) if model_kind == "mobilenet"
             else resnet(depth_blocks=(1, 1), width=8))
    loss = xent_loss(model)
    sim = FLSimulation(loss, model.init, SimConfig(n_clients=n_clients, lr=0.2,
                                                   seed=seed))
    imgs, labels = SyntheticImages(n=2048, hw=16, seed=seed).generate()
    parts = dirichlet_partition(labels, n_clients, alpha=0.5, seed=seed)
    batcher = ClientBatcher(imgs, labels, parts, batch=16, seed=seed)
    fleet = heterogeneous_fleet(n_clients, seed=seed, group_step_mhz=5.0)
    caps = memory_capacities(n_clients, lo_mb=2.0, hi_mb=8.0) * 1e6
    # error tolerance sized so the budget admits ~half the cohort at 8 bits
    # (lambda = 0.5 * e2 * d * delta_8^2; see constraint (23))
    orch = FLOrchestrator(
        OrchestratorConfig(n_devices=n_clients, n_rounds=rounds, scheme=scheme,
                           model_dim_d=1 << 16, error_tolerance=4.5, seed=seed),
        fleet, caps, grad_bytes=1e6)

    def batch_fn(r, cohort):
        x, y = batcher.sample_round(r, cohort)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    # held-out eval
    eimgs, elabels = SyntheticImages(n=512, hw=16, seed=seed + 999).generate()
    ebatch = {"x": jnp.asarray(eimgs), "y": jnp.asarray(elabels)}

    out = orch.run(sim, batch_fn,
                   eval_fn=lambda s: s.evaluate(loss, ebatch), eval_every=10)
    final_eval = out["evals"][-1] if out["evals"] else {"acc": float("nan")}
    return {
        "scheme": scheme,
        "losses": [h["loss"] for h in out["history"]],
        "final_acc": final_eval.get("acc", float("nan")),
        "total_energy_j": out["total_energy_j"],
        "total_time_s": out["total_time_s"],
    }


def main(rounds=60, out_json=""):
    results = [run_scheme(s, rounds=rounds)
               for s in ("fwq", "full_precision", "unified_q", "rand_q")]
    fwq_e = results[0]["total_energy_j"]
    for r in results:
        emit(f"fig2_{r['scheme']}", r["total_energy_j"] * 1e6,
             f"final_loss={r['losses'][-1]:.4f};acc={r['final_acc']:.3f};"
             f"energy_vs_fwq={r['total_energy_j']/max(fwq_e,1e-12):.2f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
