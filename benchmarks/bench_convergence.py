"""Paper Fig. 2: convergence + energy for FWQ vs Full-Precision / Unified-Q /
Rand-Q (CNN on synthetic-CIFAR, non-iid clients) — each scheme is one
fl-sim RunSpec through the `repro.api` facade."""

from __future__ import annotations

import json

from benchmarks.common import emit
from repro.api import RunSpec, Session


def run_scheme(scheme: str, *, n_clients=8, rounds=60, seed=0,
               model_kind="resnet"):
    """The Fig. 2 experiment recipe (shared with examples/fl_cifar_fwq.py).

    Error tolerance sized so the budget admits ~half the cohort at 8 bits
    (lambda = 0.5 * e2 * d * delta_8^2; see constraint (23)).
    """
    spec = RunSpec(
        arch=model_kind, workload="fl-sim", rounds=rounds, seed=seed,
        batch=16,
        options={"scheme": scheme, "n_clients": n_clients, "lr": 0.2,
                 "error_tolerance": 4.5, "eval_every": 10})
    out = Session(spec).run()
    final_eval = out["evals"][-1] if out["evals"] else {"acc": float("nan")}
    return {
        "scheme": scheme,
        "losses": [h["loss"] for h in out["history"]],
        "final_acc": final_eval.get("acc", float("nan")),
        "total_energy_j": out["total_energy_j"],
        "total_time_s": out["total_time_s"],
    }


def main(rounds=60, out_json=""):
    results = [run_scheme(s, rounds=rounds)
               for s in ("fwq", "full_precision", "unified_q", "rand_q")]
    fwq_e = results[0]["total_energy_j"]
    for r in results:
        emit(f"fig2_{r['scheme']}", r["total_energy_j"] * 1e6,
             f"final_loss={r['losses'][-1]:.4f};acc={r['final_acc']:.3f};"
             f"energy_vs_fwq={r['total_energy_j']/max(fwq_e,1e-12):.2f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
