"""Paper Fig. 2: convergence + energy for FWQ vs Full-Precision / Unified-Q /
Rand-Q — a thin wrapper over the ``fl-codesign-grid`` sweep preset.

The grid, execution, and result storage all live in :mod:`repro.sweep`
(cells resume by content hash, so re-running this benchmark re-uses every
completed scheme); this file only adapts stored rows to the CSV/JSON
benchmark contract.
"""

from __future__ import annotations

import json

from benchmarks.common import bench_output, emit
from repro.sweep import ResultsStore, SweepRunner, get_preset


def run_grid(rounds=60, arch="resnet", store_dir="results"):
    """Execute (or resume) the Fig. 2 scheme grid; return stored rows."""
    sweep = get_preset("fl-codesign-grid", rounds=rounds, arch=arch)
    store = ResultsStore.for_sweep(sweep, store_dir)
    SweepRunner(sweep, store, quiet=True).run()
    rows = []
    for cell in sweep.cells():
        rec = store.get(cell.key)
        if rec is None or rec["status"] != "ok":
            raise RuntimeError(f"fig2 cell failed: {cell.label}: {rec}")
        m = rec["metrics"]
        rows.append({
            "scheme": cell.spec.options["scheme"],
            "losses": m["losses"],
            "final_acc": m["final_acc"],
            "total_energy_j": m["total_energy_j"],
            "total_time_s": m["total_time_s"],
            "git_sha": rec.get("git_sha"),   # the commit that MEASURED this
        })
    return rows


def main(rounds=60, out_json=""):
    with bench_output("fig2_convergence") as jrows:
        results = run_grid(rounds=rounds)
        fwq_e = results[0]["total_energy_j"]
        for r in results:
            acc = r["final_acc"]
            emit(f"fig2_{r['scheme']}", r["total_energy_j"] * 1e6,
                 f"final_loss={r['losses'][-1]:.4f};"
                 f"acc={'-' if acc is None else f'{acc:.3f}'};"
                 f"energy_vs_fwq={r['total_energy_j']/max(fwq_e,1e-12):.2f}x")
        # resumed cells replay stored measurements: keep their git_sha
        for jr, r in zip(jrows, results):
            jr["git_sha"] = r["git_sha"] or jr["git_sha"]
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
