"""Paper Fig. 3: average energy vs number of participating devices (2..35).

More devices enrich the data (Corollary 2: fewer rounds to the target
accuracy), so the total training energy drops until the round count saturates
— reproduced with R_eps from the theory driving the energy accounting."""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import bench_output, codesign_instance, emit
from repro.core import baselines
from repro.core.convergence import ProblemConstants, corollary2_rounds
from repro.core.gbd import run_gbd


def energy_vs_users(ns=(2, 5, 10, 15, 20, 25, 30, 35), eps=0.35, seed=0):
    rows = []
    for n in ns:
        data, spec, *_ = codesign_instance(n=n, rounds=3, seed=seed)
        consts = ProblemConstants(L=1.0, tau_sq=16.0, phi=0.6, M=32, N=n,
                                  d=1 << 16, F0_minus_Fstar=2.0)
        # paper: iteration count saturates once data is rich enough
        r_eps = max(corollary2_rounds(consts, eps), 40)
        out = {"n": n, "rounds": r_eps}
        for scheme, fn in [("fwq", lambda: run_gbd(data, spec, max_rounds=20)),
                           ("full_precision", lambda: baselines.full_precision(data, spec)),
                           ("unified_q", lambda: baselines.unified_q(data, spec)),
                           ("rand_q", lambda: baselines.rand_q(data, spec, seed=seed))]:
            res = fn()
            per_round = res.energy / data.n_rounds
            out[scheme] = per_round * r_eps / n      # average per device
        rows.append(out)
    return rows


def main(out_json=""):
    with bench_output("fig3_users"):
        rows = energy_vs_users()
        for r in rows:
            emit(f"fig3_n{r['n']}", r["fwq"] * 1e6,
                 f"rounds={r['rounds']};fp={r['full_precision']:.3f}J;"
                 f"uq={r['unified_q']:.3f}J;rq={r['rand_q']:.3f}J;fwq={r['fwq']:.3f}J")
        # headline: energy decreases then saturates
        es = [r["fwq"] for r in rows]
        emit("fig3_trend", 0.0, f"first={es[0]:.3f}J;last={es[-1]:.3f}J;"
             f"monotone_drop={es[0] > es[-1]}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
